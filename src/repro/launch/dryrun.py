# The FIRST two lines — before ANY other import — force 512 placeholder
# devices so jax.make_mesh can build the production mesh (jax locks the
# device count at first init).  Never set this globally: smoke tests and
# benches must see the single real CPU device.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse    # noqa: E402
import json        # noqa: E402
import re          # noqa: E402
import time        # noqa: E402
import traceback   # noqa: E402
from pathlib import Path  # noqa: E402

import jax         # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ALL_ARCHS, get_config          # noqa: E402
from ..configs.base import SHAPES                     # noqa: E402
from ..models import build_model                      # noqa: E402
from ..parallel.sharding import axis_rules, param_sharding, resolve  # noqa: E402
from ..train.optimizer import make_optimizer          # noqa: E402
from .mesh import make_production_mesh                # noqa: E402

# ------------------------------------------------------------ HLO parsing

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str):
    """Sum output-shape bytes of every collective op in the (per-device)
    HLO module.  Returns {kind: {"bytes": int, "count": int}}."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (\w[\w-]*)\(", stripped)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2)
        # normalize: all-reduce-start / all-gather-done etc.
        base = None
        for k in _COLLECTIVES:
            if opname == k or opname.startswith(k + "-start"):
                base = k
                break
        if base is None:
            continue
        out[base]["bytes"] += _shape_bytes(result_type)
        out[base]["count"] += 1
    return out


# ------------------------------------------------------------- step fns


def make_train_step(model, optimizer):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss
    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        logits, _ = model.logits_fn(params, batch)
        return logits
    return prefill_step


def make_serve_step(model):
    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch)
    return serve_step


# ------------------------------------------------------------- dry run


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                preset: str = "baseline", verbose: bool = True,
                scan_layers: bool = False, overrides=None,
                donate: bool = False):
    """Lower + compile one (arch × shape × mesh) cell; return the record.

    Layers are UNROLLED by default (scan_layers=False): XLA's HLO cost
    analysis does not multiply while-loop bodies by their trip count, so the
    roofline terms are only trustworthy on an unrolled module."""
    cfg = get_config(arch).replace(scan_layers=scan_layers,
                                   **(overrides or {}))
    shape = cfg.shapes().get(shape_name)
    if shape is None:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": True,
                "reason": ("long_500k needs sub-quadratic attention"
                           if shape_name == "long_500k" else "not applicable")}
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()

    with axis_rules(mesh, preset=preset):
        param_shapes, param_specs = model.abstract_params()
        p_shard = param_sharding(param_specs, mesh, shapes=param_shapes)
        batch_shapes = model.input_specs(shape)
        batch_axes = model.input_axes(shape)
        b_shard = {
            k: jax.NamedSharding(mesh, resolve(batch_axes[k],
                                               batch_shapes[k].shape))
            for k in batch_shapes
        }

        if shape.kind == "train":
            optimizer = make_optimizer(cfg.optimizer)
            opt_shapes, opt_specs = optimizer.abstract_state(
                param_shapes, param_specs)
            o_shard = param_sharding(opt_specs, mesh, shapes=opt_shapes)
            fn = make_train_step(model, optimizer)
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(param_shapes, opt_shapes, batch_shapes)
        elif shape.kind == "prefill":
            fn = make_prefill_step(model)
            full_seq = (shape.seq_len if cfg.family != "vlm"
                        else shape.seq_len)
            logits_spec = jax.NamedSharding(
                mesh, resolve(("batch", "seq", "act_vocab"),
                              shape=(shape.global_batch, full_seq,
                                     cfg.vocab_size)))
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard),
                             out_shardings=logits_spec)
            lowered = jitted.lower(param_shapes, batch_shapes)
        else:  # decode
            cache_shapes, cache_specs = model.init_cache(
                shape.global_batch, shape.seq_len)
            c_shard = param_sharding(cache_specs, mesh, shapes=cache_shapes)
            fn = make_serve_step(model)
            logits_spec = jax.NamedSharding(
                mesh, resolve(("batch", "act_vocab"),
                              shape=(shape.global_batch, cfg.vocab_size)))
            jitted = jax.jit(fn, in_shardings=(p_shard, c_shard, b_shard),
                             out_shardings=(logits_spec, c_shard),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(param_shapes, cache_shapes, batch_shapes)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        # older jax returns a one-element list of dicts; newer returns the
        # dict directly — normalize so the lookups below work on both
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}

    hlo = compiled.as_text()
    colls = collective_bytes_from_hlo(hlo)

    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "multi_pod": multi_pod,
        "preset": preset,
        # scan-mode records prove compile-fit only (FLOPs undercounted —
        # the roofline table marks them)
        "scan_layers": scan_layers,
        "n_chips": int(n_chips),
        "mesh": dict(mesh.shape),
        "flops_per_device": float(cost.get("flops", -1)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1)),
        "memory": mem_info,
        "collectives": colls,
        "collective_bytes_per_device": sum(
            v["bytes"] for v in colls.values()),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "optimizer": cfg.optimizer if shape.kind == "train" else None,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × "
              f"{'2x16x16' if multi_pod else '16x16'} preset={preset}: "
              f"compile {t_compile:.1f}s, "
              f"flops/dev={record['flops_per_device']:.3e}, "
              f"coll/dev={record['collective_bytes_per_device']:.3e}B")
        print("  memory_analysis:", mem_info)
        print("  cost_analysis keys:", sorted(cost)[:12])
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, help="shape name")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) cell")
    ap.add_argument("--preset", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. remat=none)")
    ap.add_argument("--donate", action="store_true",
                    help="donate state buffers (in-place cache/param update)")
    ap.add_argument("--scan", action="store_true",
                    help="scan-over-layers (fast compile; use for pure "
                         "compile-fit verification — FLOPs undercounted)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        if v in ("false", "False"):
            v = False
        overrides[k] = v

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    # small → large so the sweep yields results early
    SIZE_ORDER = [
        "whisper-base", "tinyllama-1.1b", "zamba2-1.2b", "mamba2-1.3b",
        "olmoe-1b-7b", "qwen3-8b", "qwen3-32b", "deepseek-v2-236b",
        "qwen2-vl-72b", "llama3-405b",
    ]
    cells = []
    archs = SIZE_ORDER if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    for mp in meshes:           # single-pod sweep completes first
        for arch in archs:
            for shape in shapes:
                cells.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}__{args.preset}"
        path = outdir / f"{tag}.json"
        if args.skip_existing and path.exists() and \
                "error" not in json.loads(path.read_text()):
            continue
        try:
            rec = dryrun_cell(arch, shape, multi_pod=mp, preset=args.preset,
                              overrides=overrides, donate=args.donate,
                              scan_layers=args.scan)
        except Exception:
            failures += 1
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "error": traceback.format_exc()}
            print(f"[dryrun] FAILED {tag}")
            traceback.print_exc()
        path.write_text(json.dumps(rec, indent=2))
    print(f"[dryrun] wrote {len(cells)} records to {outdir}; "
          f"{failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
