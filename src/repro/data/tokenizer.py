"""Byte-level tokenizer (no external vocab files; offline-friendly)."""

from __future__ import annotations

from typing import List


class ByteTokenizer:
    """256 byte tokens + BOS/EOS/PAD."""

    PAD = 256
    BOS = 257
    EOS = 258

    @property
    def vocab_size(self) -> int:
        return 259

    def encode(self, text: str, add_bos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8"))
        return ([self.BOS] if add_bos else []) + ids

    def decode(self, ids) -> str:
        return bytes(t for t in ids if t < 256).decode("utf-8", "replace")
