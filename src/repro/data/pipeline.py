"""Data pipeline: deterministic synthetic LM streams + a background
prefetcher whose buffer ring is **SMR-managed** (DESIGN.md §2: a stalled I/O
thread must not leak host memory unboundedly — the same robustness property
the paper gives the KV pool).

Determinism: batch ``i`` is a pure function of (seed, i) — so restarts and
*elastic* resumes (different data-parallel size) replay identical global
batches, which the fault-tolerance tests rely on."""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from ..core.atomics import SmrNode
from ..core.smr.base import SmrScheme


def synthetic_batch(seed: int, index: int, global_batch: int, seq_len: int,
                    vocab_size: int) -> np.ndarray:
    """Markov-ish synthetic tokens: learnable structure (loss can decrease),
    deterministic in (seed, index)."""
    rng = np.random.RandomState((seed * 1_000_003 + index) % (2**31 - 1))
    base = rng.randint(0, vocab_size, size=(global_batch, 1))
    steps = rng.randint(0, 17, size=(global_batch, seq_len))
    toks = (base + np.cumsum(steps, axis=1)) % vocab_size
    return toks.astype(np.int32)


class _BufferNode(SmrNode):
    __slots__ = ("payload", "index")

    def __init__(self, payload, index):
        super().__init__()
        self.payload = payload
        self.index = index

    def reinit(self, payload, index):
        self.payload = payload
        self.index = index


class DataPipeline:
    """Iterator of (index, batch) with optional SMR-governed prefetch."""

    def __init__(self, seed: int, global_batch: int, seq_len: int,
                 vocab_size: int, start_index: int = 0,
                 prefetch: int = 4, smr: Optional[SmrScheme] = None):
        self.seed = seed
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.index = start_index
        self.prefetch = prefetch
        self.smr = smr
        self._q: "queue.Queue[_BufferNode]" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if prefetch > 0:
            self._thread = threading.Thread(target=self._producer,
                                            daemon=True)
            self._thread.start()

    def _make(self, i):
        return synthetic_batch(self.seed, i, self.global_batch,
                               self.seq_len, self.vocab_size)

    def _producer(self):
        i = self.index
        while not self._stop.is_set():
            node = _BufferNode(self._make(i), i)
            if self.smr is not None:
                self.smr.alloc_stamp(node)
            while not self._stop.is_set():
                try:
                    self._q.put(node, timeout=0.1)
                    break
                except queue.Full:
                    continue
            i += 1

    def __iter__(self) -> Iterator[np.ndarray]:
        return self

    def __next__(self) -> np.ndarray:
        if self._thread is None:
            batch = self._make(self.index)
            self.index += 1
            return batch
        while True:
            node = self._q.get()
            # skip stale buffers after a restart/seek
            if node.index < self.index:
                self._retire(node)
                continue
            self.index = node.index + 1
            payload = node.payload
            self._retire(node)
            return payload

    def _retire(self, node):
        if self.smr is not None:
            with self.smr.guard():
                self.smr.retire(node)

    def seek(self, index: int):
        """Restart/elastic resume: continue from a specific global batch."""
        self.index = index

    def close(self):
        self._stop.set()
        if self._thread is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=5)
