"""Checkpointing for fault tolerance + elastic scaling.

Design (matches what a 1000-node deployment needs, scaled to files):
  * **atomic**: write to ``step_N.tmp/`` then ``os.replace`` → ``step_N/``;
    a crash mid-write never corrupts the latest checkpoint;
  * **async**: ``save()`` snapshots host arrays and hands off to a writer
    thread — the train loop never blocks on I/O;
  * **self-describing**: a manifest carries step, data index, mesh shape and
    the *logical axis spec* of every leaf, so ``restore()`` can re-shard onto
    a DIFFERENT mesh (elastic scale-up/down) by re-resolving the logical
    specs against the new mesh;
  * **bounded**: keeps the last ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, value in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return root


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._writer: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, params, opt_state, *, data_index: int = 0,
             param_specs=None, extra: Optional[dict] = None,
             block: bool = False) -> None:
        self.wait()  # one in-flight save at a time
        # snapshot to host BEFORE returning (params may be donated/updated)
        flat_p = {k: np.asarray(v) for k, v in _flatten(params).items()}
        flat_o = {k: np.asarray(v) for k, v in _flatten(opt_state).items()}
        manifest = {
            "step": step,
            "data_index": data_index,
            "time": time.time(),
            "extra": extra or {},
            "param_specs": {k: list(v) for k, v in
                            _flatten(param_specs or {}).items()},
        }

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "params.npz", **flat_p)
            np.savez(tmp / "opt_state.npz", **flat_o)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)           # atomic publish
            self._gc()

        if self.async_save and not block:
            self._writer = threading.Thread(target=self._guarded, args=(write,),
                                            daemon=True)
            self._writer.start()
        else:
            write()

    def _guarded(self, fn):
        try:
            fn()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if not p.name.endswith(".tmp")]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, *, mesh=None,
                param_specs=None, opt_specs=None,
                resolve_fn=None) -> Tuple[Any, Any, dict]:
        """Load (params, opt_state, manifest).  With ``mesh`` +
        ``param_specs`` + ``resolve_fn`` (repro.parallel.sharding.resolve),
        leaves are device_put with shardings re-resolved on the *current*
        mesh — this is the elastic-resume path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step}"
        manifest = json.loads((path / "manifest.json").read_text())
        flat_p = dict(np.load(path / "params.npz"))
        flat_o = dict(np.load(path / "opt_state.npz"))

        def maybe_shard(flat, specs):
            if mesh is None or specs is None or resolve_fn is None:
                return {k: jax.numpy.asarray(v) for k, v in flat.items()}
            flat_specs = _flatten(specs)
            out = {}
            for k, v in flat.items():
                ax = tuple(flat_specs.get(k, ()) or (None,) * v.ndim)
                sh = jax.NamedSharding(mesh, resolve_fn(ax, v.shape))
                out[k] = jax.device_put(v, sh)
            return out

        params = _unflatten(maybe_shard(flat_p, param_specs))
        opt_state = _unflatten(maybe_shard(flat_o, opt_specs))
        # np.savez stringifies scalars; restore count dtype
        return params, opt_state, manifest
