"""``repro.api`` — the one construction surface for concurrent maps.

The paper's claim is that SCOT keeps SMR schemes *intact* while making
structures compatible; this facade is where that compatibility is
**negotiated** instead of assumed.  Everything the serving engine, the
workload driver, the benchmarks and the examples build goes through::

    from repro import api

    smr = api.scheme("IBR", retire_scan_freq=16)
    ds  = api.build("HList", smr=smr, traversal="waitfree")

``build`` resolves through two registries — schemes declare capabilities
(robustness, cumulative protection, reclaiming, batch-hint legality, slot
count), structures declare requirements (slot budget, supported traversal
policies) — and fails fast with an :class:`IncompatiblePairError`
diagnostic on illegal pairs, e.g. the Figure-1 pair (unvalidated
optimistic traversal under a robust scheme)::

    api.build("HList", smr="HP", traversal="optimistic")
    # IncompatiblePairError: traversal 'optimistic' skips SCOT validation,
    # which is a use-after-free under robust scheme HP (paper Fig. 1); ...

Traversal strategies are named policy objects (``"optimistic"``,
``"scot"``, ``"hm"``, ``"waitfree"`` — see
:mod:`repro.core.structures.traversal` and DESIGN.md §10 for the
wait-free variant), replacing the old ``scot=``/``recovery=`` boolean
soup.  Capability queries (``api.schemes(robust=True)``) replace the
hardcoded scheme lists the benchmarks used to carry.

Direct structure construction (``HarrisList(smr, ...)``) remains available
as the *unguarded* layer — the legacy boolean kwargs still work for one
release (with a ``DeprecationWarning``) and deliberately bypass
negotiation; that is how the Figure-1 demonstrations build the known-unsafe
pair.  Through the facade the same escape hatch is ``allow_unsafe=True``.
"""

from __future__ import annotations

from typing import Optional, Union

from ..core.smr.base import SmrScheme
from ..core.structures.traversal import (
    CarefulHM,
    IncompatiblePairError,
    OptimisticSCOT,
    PlainOptimistic,
    TraversalPolicy,
    WaitFreeSCOT,
    as_policy,
    default_policy,
)
from .registry import (
    SCHEME_REGISTRY,
    STRUCTURE_REGISTRY,
    SchemeInfo,
    StructureInfo,
    _make_scheme,
    capability_matrix,
    check,
    compatible,
    scheme_info,
    schemes,
    structure_info,
    structures,
    traversal_policies,
)

__all__ = [
    "IncompatiblePairError",
    "TraversalPolicy",
    "PlainOptimistic",
    "OptimisticSCOT",
    "CarefulHM",
    "WaitFreeSCOT",
    "SchemeInfo",
    "StructureInfo",
    "build",
    "scheme",
    "schemes",
    "structures",
    "traversal_policies",
    "admission_policies",
    "eviction_policies",
    "scheduler_policies",
    "sampling_policies",
    "fault_kinds",
    "scheme_info",
    "structure_info",
    "check",
    "compatible",
    "capability_matrix",
    "as_policy",
    "default_policy",
]


def admission_policies():
    """Serving admission-policy names (registry query, like
    :func:`traversal_policies`).  Lazy import: the serving layer depends on
    this facade, not the other way round."""
    from ..serving.policies import admission_policies as _q
    return _q()


def eviction_policies():
    """Prefix-cache eviction-policy names (registry query)."""
    from ..runtime.eviction import eviction_policies as _q
    return _q()


def scheduler_policies():
    """Chunked-prefill scheduler-policy names (registry query)."""
    from ..serving.policies import scheduler_policies as _q
    return _q()


def sampling_policies():
    """Serving sampling-policy names (registry query — the replay-exact
    on-device sampling registry, DESIGN.md §17)."""
    from ..serving.sampling import sampling_policies as _q
    return _q()


def fault_kinds():
    """Chaos-injection fault kinds (registry query — the serving fault
    plan, ``ServingConfig.faults`` / ``serve_paged --fault``)."""
    from ..serving.faults import fault_kinds as _q
    return _q()


def scheme(name: Union[str, SmrScheme] = "EBR", **kwargs) -> SmrScheme:
    """Construct (or pass through) an SMR scheme by registry name.

    The only sanctioned string→scheme resolution outside ``repro.core`` —
    consumers use this instead of private ``SCHEMES[...]`` lookups."""
    if isinstance(name, SmrScheme):
        if kwargs:
            raise TypeError("scheme(): kwargs make no sense with an "
                            "already-constructed scheme instance")
        return name
    return _make_scheme(scheme_info(name).name, **kwargs)


def build(structure: str = "HList",
          smr: Union[str, SmrScheme] = "EBR",
          traversal: Union[str, TraversalPolicy, None] = None,
          *,
          smr_kwargs: Optional[dict] = None,
          allow_unsafe: bool = False,
          **structure_kwargs):
    """Negotiate and construct a concurrent map.

    Parameters
    ----------
    structure:  registry name — ``api.structures()`` lists them.
    smr:        scheme name (constructed via ``smr_kwargs``) or a live
                :class:`SmrScheme` instance to share across structures.
    traversal:  policy name or :class:`TraversalPolicy` instance; ``None``
                picks the paper's default (SCOT iff the scheme is robust).
    allow_unsafe:  opt into a combination the negotiation would reject
                (e.g. the Figure-1 unvalidated-optimistic-under-HP pair)
                for demos and safety tests.
    **structure_kwargs:  forwarded to the structure constructor
                (``recycle=``, ``num_buckets=``, ``max_height=``, …).

    Raises :class:`IncompatiblePairError` on an illegal triple and
    ``ValueError`` on unknown names.
    """
    if isinstance(smr, SmrScheme):
        if smr_kwargs:
            raise TypeError("build(): smr_kwargs make no sense with an "
                            "already-constructed scheme instance")
        s = smr
    else:
        s = _make_scheme(scheme_info(smr).name, **(smr_kwargs or {}))
    entry = structure_info(structure)
    policy = check(structure, s, traversal, allow_unsafe=allow_unsafe)
    return entry.cls(s, policy=policy, **structure_kwargs)
