"""Capability-negotiated registries behind :func:`repro.api.build`.

Two registries, one negotiation:

* **Schemes** declare capabilities *on their classes* (``robust``,
  ``cumulative_protection``, ``reclaims``, ``batch_hints``, and the slot
  count an instance reserves) — this module only *reads* them, so adding a
  scheme to ``repro.core.smr.SCHEMES`` automatically updates every
  registry query (and therefore every benchmark grid built from one).
* **Structures** declare requirements: the traversal policies they can run
  (``cls.POLICIES``) and their hazard-slot budget per policy
  (``cls.slots_needed``).

:func:`check` is the single place the two meet.  Illegal combinations fail
fast with :class:`IncompatiblePairError` diagnostics instead of the old
scattered ``if scheme in (...)`` guards (or, worse, a silent Figure-1
use-after-free at runtime).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

from ..core.smr import SCHEMES as _SCHEME_CLASSES
from ..core.smr import make_scheme
from ..core.smr.base import SmrScheme
from ..core.structures import (
    HarrisList,
    HarrisMichaelList,
    LockFreeHashMap,
    NMTree,
    SkipList,
)
from ..core.structures.traversal import (
    POLICY_NAMES,
    IncompatiblePairError,
    TraversalPolicy,
    as_policy,
    default_policy,
)

__all__ = [
    "SchemeInfo",
    "StructureInfo",
    "scheme_info",
    "structure_info",
    "schemes",
    "structures",
    "traversal_policies",
    "check",
    "compatible",
    "capability_matrix",
]


@dataclass(frozen=True)
class SchemeInfo:
    """A scheme's registry entry — capabilities read off its class."""

    name: str
    cls: type
    robust: bool
    cumulative_protection: bool
    reclaims: bool
    batch_hints: str
    default_slots: int


@dataclass(frozen=True)
class StructureInfo:
    """A structure's registry entry — requirements read off its class."""

    name: str
    cls: type
    policies: Tuple[str, ...]
    description: str

    def slots_needed(self, policy: TraversalPolicy) -> int:
        return self.cls.slots_needed(policy)


def _default_slots(cls: type) -> int:
    """The slot count an instance constructed with no arguments reserves —
    read off the constructor signature (walking the MRO past ``*args``
    forwarders like Hyaline1S) so name-based negotiation can never drift
    from what ``make_scheme(name)`` actually builds."""
    import inspect
    for klass in cls.__mro__:
        params = inspect.signature(klass.__init__).parameters
        p = params.get("num_slots")
        if p is not None and p.default is not inspect.Parameter.empty:
            return p.default
    raise TypeError(f"{cls.__name__}: no num_slots constructor default")


def _scheme_entry(name: str, cls: type) -> SchemeInfo:
    caps = cls.capabilities()
    return SchemeInfo(
        name=name, cls=cls, robust=caps["robust"],
        cumulative_protection=caps["cumulative_protection"],
        reclaims=caps["reclaims"], batch_hints=caps["batch_hints"],
        default_slots=_default_slots(cls),
    )


SCHEME_REGISTRY: Dict[str, SchemeInfo] = {
    name: _scheme_entry(name, cls) for name, cls in _SCHEME_CLASSES.items()
}

STRUCTURE_REGISTRY: Dict[str, StructureInfo] = {
    "HList": StructureInfo(
        "HList", HarrisList, HarrisList.POLICIES,
        "Harris' lock-free ordered list (optimistic traversals)"),
    "HMList": StructureInfo(
        "HMList", HarrisMichaelList, HarrisMichaelList.POLICIES,
        "Harris-Michael list (careful traversals — the baseline)"),
    "NMTree": StructureInfo(
        "NMTree", NMTree, NMTree.POLICIES,
        "Natarajan-Mittal external BST (optimistic traversals)"),
    "SkipList": StructureInfo(
        "SkipList", SkipList, SkipList.POLICIES,
        "Fraser-style skip list (per-level Harris traversals)"),
    "HashMap": StructureInfo(
        "HashMap", LockFreeHashMap, LockFreeHashMap.POLICIES,
        "bucketed lock-free hash map (delegates to the lists)"),
}


# ----------------------------------------------------------------- lookups
def scheme_info(name: Union[str, SmrScheme]) -> SchemeInfo:
    if isinstance(name, SmrScheme):
        name = name.name
    try:
        return SCHEME_REGISTRY[name.upper()]
    except KeyError:
        raise ValueError(f"unknown SMR scheme {name!r}; choose from "
                         f"{list(SCHEME_REGISTRY)}")


def structure_info(name: str) -> StructureInfo:
    try:
        return STRUCTURE_REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown structure {name!r}; choose from "
                         f"{list(STRUCTURE_REGISTRY)}")


# ----------------------------------------------------------------- queries
def schemes(*, robust: Optional[bool] = None,
            cumulative_protection: Optional[bool] = None,
            reclaims: Optional[bool] = None,
            batch_hints: Optional[str] = None) -> List[str]:
    """Scheme names filtered by capability (registration order).

    ``api.schemes(robust=True)`` is the benchmark grids' replacement for
    the hardcoded ``SCOT_SCHEMES`` lists: a newly registered scheme shows
    up in every grid automatically.
    """
    out = []
    for e in SCHEME_REGISTRY.values():
        if robust is not None and e.robust != robust:
            continue
        if cumulative_protection is not None \
                and e.cumulative_protection != cumulative_protection:
            continue
        if reclaims is not None and e.reclaims != reclaims:
            continue
        if batch_hints is not None and e.batch_hints != batch_hints:
            continue
        out.append(e.name)
    return out


def structures(*, policy: Optional[str] = None) -> List[str]:
    """Structure names, optionally filtered by supported traversal policy."""
    return [e.name for e in STRUCTURE_REGISTRY.values()
            if policy is None or policy in e.policies]


def traversal_policies() -> List[str]:
    return list(POLICY_NAMES)


# ------------------------------------------------------------- negotiation
def check(structure: str, smr: Union[str, SmrScheme],
          traversal: Union[str, TraversalPolicy, None] = None,
          *, allow_unsafe: bool = False) -> TraversalPolicy:
    """Negotiate one (structure, scheme, policy) triple.

    Returns the resolved :class:`TraversalPolicy` or raises
    :class:`IncompatiblePairError` with a diagnostic.  ``smr`` may be a
    name (negotiated against the scheme's default slot count) or a live
    instance (negotiated against its actual ``num_slots``).
    """
    s_entry = structure_info(structure)
    sch = scheme_info(smr)
    num_slots = smr.num_slots if isinstance(smr, SmrScheme) \
        else sch.default_slots

    if traversal is None:
        # the paper's default: SCOT iff the scheme is robust — except for
        # structures that ARE one policy (HMList runs 'hm' or nothing)
        policy = as_policy(s_entry.policies[0]) \
            if len(s_entry.policies) == 1 else default_policy(sch.cls)
    else:
        policy = as_policy(traversal)

    if policy.name not in s_entry.policies:
        raise IncompatiblePairError(
            f"{s_entry.name} does not support traversal policy "
            f"{policy.name!r}; supported: {list(s_entry.policies)}",
            structure=s_entry.name, scheme=sch.name, policy=policy.name)

    if not policy.validates and not policy.careful and sch.robust \
            and not allow_unsafe:
        raise IncompatiblePairError(
            f"traversal {policy.name!r} skips SCOT validation, which is a "
            f"use-after-free under robust scheme {sch.name} (paper Fig. 1);"
            f" choose 'scot' or 'waitfree', a non-robust scheme "
            f"({schemes(robust=False)}), or pass allow_unsafe=True to "
            f"reproduce the bug deliberately",
            structure=s_entry.name, scheme=sch.name, policy=policy.name)

    needed = s_entry.slots_needed(policy)
    if num_slots < needed:
        raise IncompatiblePairError(
            f"{s_entry.name} with traversal {policy.name!r} needs {needed} "
            f"reservation slots; scheme {sch.name} reserves only "
            f"{num_slots} (construct it with num_slots>={needed})",
            structure=s_entry.name, scheme=sch.name, policy=policy.name)

    return policy


def compatible(structure: str, smr: Union[str, SmrScheme],
               traversal: Union[str, TraversalPolicy, None] = None
               ) -> Tuple[bool, Optional[str]]:
    """Non-raising :func:`check`: ``(True, None)`` or ``(False, reason)``."""
    try:
        check(structure, smr, traversal)
        return (True, None)
    except IncompatiblePairError as e:
        return (False, e.reason)


def capability_matrix() -> Dict[str, object]:
    """The full negotiated surface, machine-readable (renders API.md §3)."""
    pairs = []
    for s in STRUCTURE_REGISTRY:
        for pol in POLICY_NAMES:
            for sch in SCHEME_REGISTRY:
                ok, reason = compatible(s, sch, pol)
                pairs.append({"structure": s, "traversal": pol,
                              "scheme": sch, "ok": ok, "reason": reason})
    return {
        "schemes": {n: e.cls.capabilities()
                    for n, e in SCHEME_REGISTRY.items()},
        "structures": {n: {"policies": list(e.policies),
                           "description": e.description}
                       for n, e in STRUCTURE_REGISTRY.items()},
        "pairs": pairs,
    }


# re-exported for the facade
_make_scheme = make_scheme
